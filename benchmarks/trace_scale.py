"""Trace-replay throughput and calibration-fidelity benchmark: time
`repro.traces` replay through both fleet scans — `simulate_fleet` under
`TraceHarvest` and `simulate_serve` under `TraceTraffic` + `TraceHarvest` —
at N in {1e3, 1e5, 1e6} clients host-local, plus, whenever more than one
device is visible (CI runs an ``XLA_FLAGS=--xla_force_host_platform_
device_count=8`` job), a ``sharded`` section sweeping the mesh-sharded
client axis at >= 1e6 clients x >= 50 epochs.

A ``calibration`` section records estimator fidelity per PR: each synthetic
process is re-fit from its own sampled paths (`fit_markov_solar` /
`fit_diurnal_poisson` / `fit_mmpp`) and the true-vs-fitted parameters land
in the artifact, so a regression in recovery error (not just speed) is
visible in the ``BENCH_traces.json`` diff — uploaded per PR by CI's
``trace-scale`` job.

Usage:
    PYTHONPATH=src python benchmarks/trace_scale.py            # full sweep
    PYTHONPATH=src python benchmarks/trace_scale.py --smoke    # CI (~seconds)
"""
from __future__ import annotations

import argparse
import contextlib
import json
import time

import jax
import numpy as np

from repro.core import Policy
from repro.energy import (BatteryConfig, DecodeCostModel, FleetConfig,
                          MarkovSolar, TraceHarvest, simulate_fleet)
from repro.serve import (MMPP, BatteryGated, DiurnalPoisson, QoSSpec,
                         ServeConfig, TraceTraffic, simulate_serve)
from repro.traces import (fit_diurnal_poisson, fit_markov_solar, fit_mmpp,
                          request_profile_table, rescale, sample_paths,
                          solar_profile_table)

QOS = QoSSpec(prompt_tokens=128.0, full_decode_tokens=256.0,
              short_decode_tokens=32.0)
COST = DecodeCostModel.from_params(1e8)


def _procs(n, seed=0):
    solar = rescale(solar_profile_table(), 1.5)
    requests = rescale(request_profile_table(), 1.0)
    return (TraceHarvest.create(solar, n, seed=seed, gain_jitter=0.3),
            TraceTraffic.create(requests, n, seed=seed, gain_jitter=0.3))


def bench_fleet(n: int, rounds: int, seed: int = 0, mesh=None) -> dict:
    harvest, _ = _procs(n, seed)
    bat = BatteryConfig(capacity=4.0, leak=0.01, init_charge=1.0)
    cfg = FleetConfig(num_clients=n, policy=Policy.THRESHOLD, seed=seed)

    def run():
        return simulate_fleet(harvest, bat, 1.0, cfg, rounds, mesh=mesh)

    t0 = time.perf_counter()
    res = run()                      # compile + first run
    t1 = time.perf_counter()
    res = run()                      # steady state (jit cache hit)
    t2 = time.perf_counter()
    wall = t2 - t1
    rec = {
        "scan": "fleet", "num_clients": n, "rounds": rounds,
        "compile_plus_run_s": round(t1 - t0, 4),
        "run_s": round(wall, 4),
        "rounds_per_s": round(rounds / wall, 2),
        "client_rounds_per_s": round(n * rounds / wall, 1),
        "participation": float(res.stats["participants"].mean() / n),
        "frac_depleted": float(res.stats["frac_depleted"].mean()),
    }
    if mesh is not None:
        rec["mesh_devices"] = int(np.prod(list(mesh.shape.values())))
    return rec


def bench_serve(n: int, epochs: int, seed: int = 0, mesh=None) -> dict:
    harvest, traffic = _procs(n, seed)
    bat = BatteryConfig(capacity=8.0, leak=0.01, init_charge=2.0)
    cfg = ServeConfig(num_clients=n, seed=seed)
    pol = BatteryGated.create(n, hi=2.0, lo=1.5)

    def run():
        return simulate_serve(traffic, harvest, bat, COST, QOS, pol, cfg,
                              epochs, mesh=mesh)

    t0 = time.perf_counter()
    res = run()
    t1 = time.perf_counter()
    res = run()
    t2 = time.perf_counter()
    wall = t2 - t1
    s = res.stats
    offered = max(float(s["offered"].sum()), 1e-9)
    rec = {
        "scan": "serve", "num_clients": n, "epochs": epochs,
        "compile_plus_run_s": round(t1 - t0, 4),
        "run_s": round(wall, 4),
        "epochs_per_s": round(epochs / wall, 2),
        "client_epochs_per_s": round(n * epochs / wall, 1),
        "served_rate": float((s["served_full"].sum()
                              + s["served_short"].sum()) / offered),
        "shed_rate": float(s["shed"].sum() / offered),
        "joules_per_token": res.joules_per_token,
    }
    if mesh is not None:
        rec["mesh_devices"] = int(np.prod(list(mesh.shape.values())))
    return rec


def bench_calibration(fit_n: int, fit_r: int) -> dict:
    """Round-trip fidelity: fit each synthetic process on its own sampled
    paths and record true vs fitted parameters (+ wall time), so estimator
    regressions show in the artifact diff."""
    out = {"fit_clients": fit_n, "fit_rounds": fit_r}

    true_solar = {"p_stay_day": 0.9, "p_stay_night": 0.85, "day_mean": 1.2,
                  "night_mean": 0.05}
    proc = MarkovSolar.create(fit_n, **true_solar)
    t0 = time.perf_counter()
    fit = fit_markov_solar(sample_paths(proc, fit_r, seed=1), 1)
    out["markov_solar"] = {
        "true": true_solar, "fit_s": round(time.perf_counter() - t0, 3),
        "fitted": {k: round(float(getattr(fit, k)[0]), 4)
                   for k in true_solar}}

    true_diurnal = {"base": 1.0, "swing": 0.7, "phase": 9.0}
    proc = DiurnalPoisson.create(fit_n, **true_diurnal)
    t0 = time.perf_counter()
    fit = fit_diurnal_poisson(sample_paths(proc, fit_r, seed=2), 1)
    out["diurnal_poisson"] = {
        "true": true_diurnal, "fit_s": round(time.perf_counter() - t0, 3),
        "fitted": {k: round(float(getattr(fit, k)[0]), 4)
                   for k in true_diurnal}}

    true_mmpp = {"p_stay_calm": 0.9, "p_stay_burst": 0.7, "calm_rate": 0.4,
                 "burst_rate": 4.0}
    proc = MMPP.create(fit_n, **true_mmpp)
    t0 = time.perf_counter()
    fit = fit_mmpp(sample_paths(proc, fit_r, seed=3), 1)
    out["mmpp"] = {
        "true": true_mmpp, "fit_s": round(time.perf_counter() - t0, 3),
        "fitted": {k: round(float(getattr(fit, k)[0]), 4)
                   for k in true_mmpp}}
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (seconds, not minutes)")
    ap.add_argument("--out", default="BENCH_traces.json")
    ap.add_argument("--epochs", type=int, default=96)
    ap.add_argument("--history", default=None,
                    help="append this run's headline numbers (+ manifest "
                         "git rev) as one JSON line to the given "
                         "BENCH_history.jsonl — the committed bench "
                         "trajectory `repro.obs.report trend` renders")
    ap.add_argument("--obs-dir", default=None,
                    help="also stream bench progress as a repro.obs JSONL "
                         "event log (manifest + per-section spans + "
                         "per-record events)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="persist each completed bench record so a killed "
                         "run resumes past the sections it already measured "
                         "(repro.checkpoint.SectionCheckpoint)")
    ap.add_argument("--resume", action="store_true",
                    help="replay completed records from --checkpoint-dir and "
                         "only compute the rest")
    args = ap.parse_args()

    if args.resume and not args.checkpoint_dir:
        ap.error("--resume requires --checkpoint-dir")
    sc = None
    if args.checkpoint_dir:
        from repro.checkpoint import SectionCheckpoint
        from repro.obs.events import pytree_hash
        sc = SectionCheckpoint(
            args.checkpoint_dir, kind="trace_scale",
            config_hash=pytree_hash(("trace_scale", bool(args.smoke),
                                     int(args.epochs))),
            resume=args.resume)
        if sc.resumed:
            done = {k: len(v) for k, v in sc.sections.items()}
            print(f"resuming: replaying completed records {done}")

    def cached(section, index, fn):
        return sc.cached(section, index, fn) if sc is not None else fn()

    from repro.obs import Obs, RunManifest
    obs = Obs(args.obs_dir) if args.obs_dir else None
    manifest = RunManifest.create("trace_scale", horizon=args.epochs,
                                  smoke=args.smoke)
    if obs is not None:
        if sc is not None and sc.resumed:
            obs.event("resume", run_kind="trace_scale", step=sc.step,
                      config_hash=sc.config_hash,
                      checkpoint_dir=args.checkpoint_dir)
        else:
            manifest = obs.write_manifest("trace_scale", horizon=args.epochs,
                                          smoke=args.smoke)

    def _span(name):
        return obs.span(name) if obs is not None else contextlib.nullcontext()

    def _note(section, rec):
        if obs is not None:
            obs.event("bench_record", section=section,
                      **{k: v for k, v in rec.items()
                         if isinstance(v, (int, float, str, bool))})

    if args.smoke:
        sizes = [1_000, 100_000]
        # acceptance: a >= 1e6-client x >= 50-epoch sharded sweep in CI's
        # 8-device emulated job
        sharded = [(1_000_000, max(50, args.epochs // 2))]
        fit_n, fit_r = 128, 192
    else:
        sizes = [1_000, 100_000, 1_000_000]
        sharded = [(1_000_000, args.epochs), (10_000_000, args.epochs)]
        fit_n, fit_r = 256, 480

    results = []
    for n in sizes:
        for bench in (bench_fleet, bench_serve):
            with _span("results"):
                rec = cached("results", len(results),
                             lambda n=n, bench=bench: bench(n, args.epochs))
            results.append(rec)
            _note("results", rec)
            per_s = rec.get("client_rounds_per_s",
                            rec.get("client_epochs_per_s"))
            print(f"N={n:>9,} {rec['scan']:>6} run={rec['run_s']:.3f}s  "
                  f"client-steps/s={per_s:.2e}", flush=True)

    sharded_results = []
    n_dev = jax.device_count()
    if n_dev > 1:
        mesh = jax.make_mesh((n_dev,), ("data",))
        for n, epochs in sharded:
            with _span("sharded"):
                rec = cached("sharded", len(sharded_results),
                             lambda n=n, e=epochs:
                             bench_serve(n, e, mesh=mesh))
            sharded_results.append(rec)
            _note("sharded", rec)
            print(f"N={n:>9,}  serve sharded/{n_dev}dev epochs={epochs} "
                  f"run={rec['run_s']:.3f}s  "
                  f"client-epochs/s={rec['client_epochs_per_s']:.2e}",
                  flush=True)
    else:
        print("single device: skipping sharded section "
              "(set XLA_FLAGS=--xla_force_host_platform_device_count=8)")

    with _span("calibration"):
        cal = cached("calibration", 0,
                     lambda: bench_calibration(fit_n, fit_r))
    for name in ("markov_solar", "diurnal_poisson", "mmpp"):
        print(f"calibration {name}: true={cal[name]['true']} "
              f"fitted={cal[name]['fitted']} ({cal[name]['fit_s']}s)",
              flush=True)

    out = {"bench": "trace_scale", "smoke": args.smoke, "epochs": args.epochs,
           "devices": n_dev, "manifest": manifest.to_dict(),
           "results": results, "sharded": sharded_results,
           "calibration": cal}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    if obs is not None:
        obs.close()
    print(f"wrote {args.out}")

    if args.history:
        try:                              # `python -m benchmarks.trace_scale`
            from benchmarks._fmt import append_history
        except ImportError:               # `python benchmarks/trace_scale.py`
            from _fmt import append_history
        fleet = [r for r in results if r["scan"] == "fleet"]
        serve = [r for r in results if r["scan"] == "serve"]
        append_history(args.history, "trace_scale", {
            "max_client_rounds_per_s": max(r["client_rounds_per_s"]
                                           for r in fleet),
            "max_client_epochs_per_s": max(r["client_epochs_per_s"]
                                           for r in serve),
            "solar_day_mean_abs_err": round(abs(
                cal["markov_solar"]["fitted"]["day_mean"]
                - cal["markov_solar"]["true"]["day_mean"]), 4),
        }, out["manifest"], smoke=args.smoke)
        print(f"appended headline to {args.history}")


if __name__ == "__main__":
    main()
