"""Shared table/manifest formatting for the benchmark suite.

One source of truth for the fixed-width text tables (`benchmarks.roofline`)
and the markdown tables (`benchmarks.report`) that used to be hand-rolled
in each module, plus `manifest_line` — the renderer for the provenance
manifest block PR 7 embeds in every ``BENCH_*.json`` (`repro.obs.events
.RunManifest`) — and `append_history`, the one-line-per-run JSONL appender
behind the committed ``BENCH_history.jsonl`` trajectory file that
``repro.obs.report trend`` renders.  All of it is stdlib-only:
`benchmarks.run` imports the roofline module without repro on the path.
"""
from __future__ import annotations

import json
import time


def text_table(headers: list[str], rows: list[list], align: str | None = None
               ) -> str:
    """Fixed-width text table over pre-formatted cells.

    ``align`` is one '<'/'>' per column (default: first column left, the
    rest right — the numeric-table convention of the roofline output).
    """
    cells = [[str(h) for h in headers]] + [[str(c) for c in r] for r in rows]
    ncol = len(headers)
    if align is None:
        align = "<" + ">" * (ncol - 1)
    widths = [max(len(r[i]) for r in cells) for i in range(ncol)]
    lines = ["  ".join(format(c, f"{a}{w}")
                       for c, a, w in zip(row, align, widths)).rstrip()
             for row in cells]
    lines.insert(1, "-" * len(lines[0]))
    return "\n".join(lines)


def md_table(headers: list[str], rows: list[list]) -> str:
    """GitHub-markdown table over pre-formatted cells."""
    out = ["| " + " | ".join(str(h) for h in headers) + " |",
           "|" + "---|" * len(headers)]
    out += ["| " + " | ".join(str(c) for c in row) + " |" for row in rows]
    return "\n".join(out)


def manifest_line(bench: dict) -> str:
    """One provenance line from a BENCH dict's embedded ``manifest`` block.

    Pre-PR-7 BENCH files have no manifest — those (and any malformed block)
    render as an explicit placeholder instead of crashing the report.
    """
    man = bench.get("manifest") if isinstance(bench, dict) else None
    if not isinstance(man, dict):
        return "(no manifest — pre-PR-7 BENCH file)"
    pkgs = man.get("packages") or {}
    mesh = man.get("mesh_shape")
    return (f"run {man.get('run_id', '?')}: git={man.get('git_rev', '?')} "
            f"jax={pkgs.get('jax', '?')} backend={man.get('jax_backend', '?')} "
            f"devices={man.get('device_count', '?')} "
            f"mesh={mesh if mesh else 'host-local'} "
            f"config_hash={man.get('config_hash', '?')}")


def append_history(path: str, bench: str, headline: dict,
                   manifest: dict | None = None, **extra) -> dict:
    """Append one bench-trajectory record to a ``BENCH_history.jsonl``.

    One JSON line per bench run: the bench name, the manifest's git rev and
    run id (provenance — which commit produced these numbers), a UTC
    timestamp, and a flat ``headline`` dict of the few numbers worth
    tracking across commits.  ``repro.obs.report trend`` renders the file;
    records are append-only so the committed history is a merge-friendly
    log, not a mutable table.  Returns the record written.
    """
    man = manifest if isinstance(manifest, dict) else {}
    rec = {
        "bench": bench,
        "git_rev": man.get("git_rev"),
        "run_id": man.get("run_id"),
        "recorded": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "headline": {k: v for k, v in headline.items() if v is not None},
    }
    rec.update(extra)
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")
    return rec
