"""Benchmark harness: one benchmark per paper table/figure + system
microbenches.  Prints ``name,us_per_call,derived`` CSV rows.

The paper has one experimental artifact (Figure 1: test accuracy vs global
rounds for Algorithm 1 vs two energy-agnostic benchmarks and unconstrained
FedAvg) — ``fig1`` reproduces it.  The other rows benchmark the system
substrate (scheduler, aggregation, local update, kernels-oracle paths) and
summarise the dry-run roofline table when its JSONs exist.

Scale: REPRO_BENCH_SCALE=quick (default, ~5 min CPU) | paper (full §V scale).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

SCALE = os.environ.get("REPRO_BENCH_SCALE", "quick")


def _timeit(fn, *args, n=50, warmup=2):
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def bench_fig1():
    """Paper Figure 1 (the single experimental figure)."""
    from benchmarks.fig1 import run_fig1
    kw = dict(num_clients=40, taus=(1, 5, 10, 20), local_steps=5, seed=0,  # noqa: E501
              verbose=False, out_json="benchmarks/results/fig1_bench.json")
    if SCALE == "paper":
        kw.update(batch=32, rounds=200, num_train=50000, num_test=10000,
                  eval_every=20)
    elif SCALE == "smoke":
        kw.update(num_clients=16, taus=(1, 2, 4, 8), batch=4, rounds=12,
                  num_train=1200, num_test=400, eval_every=4)
    else:
        kw.update(batch=8, rounds=30, num_train=4000, num_test=1000,
                  eval_every=10)
    t0 = time.time()
    results = run_fig1(**kw)
    wall = (time.time() - t0) * 1e6
    rows = []
    for policy, r in results.items():
        rows.append((f"fig1/{policy}", r["wall_s"] * 1e6 / max(kw['rounds'], 1),
                     f"final_acc={r['final_acc']:.3f}"))
    # the paper's ordering claim: alg1 > both benchmarks, ~fedavg
    a = {k: results[k]["final_acc"] for k in results}
    ordering = (a["sustainable"] > a["greedy"] and
                a["sustainable"] > a["wait_all"])
    rows.append(("fig1/ordering_check", wall,
                 f"alg1_beats_benchmarks={ordering};accs=" +
                 ";".join(f"{k}:{v:.3f}" for k, v in a.items())))
    return rows


def bench_scheduler():
    """Scheduling decision cost (the paper stresses 'no coordination')."""
    from repro.core import participation_mask
    E = jnp.asarray([(1, 5, 10, 20)[i % 4] for i in range(1024)], jnp.int32)
    f = jax.jit(lambda r: participation_mask("sustainable", 0, r, E))
    us = _timeit(f, jnp.int32(7), n=200)
    return [("scheduler/mask_1024_clients", us, "stateless;per-round")]


def bench_aggregation():
    """Server aggregation (eq. 13) on a 1M-param model, 16 clients."""
    from repro.core import aggregate
    C, M = 16, 1_000_000
    key = jax.random.PRNGKey(0)
    w = {"w": jax.random.normal(key, (M,))}
    ws = {"w": jax.random.normal(key, (C, M))}
    p = jnp.ones((C,)) / C
    E = jnp.asarray([1, 5, 10, 20] * 4, jnp.float32)
    mask = jnp.ones((C,))
    f = jax.jit(lambda w, ws: aggregate(w, ws, mask, p, E))
    us = _timeit(f, w, ws, n=20)
    gb = (C * M * 4 + 2 * M * 4) / 1e9
    return [("aggregation/16x1M", us, f"hbm_gb={gb:.3f};"
             f"gbps={gb / (us / 1e6):.1f}")]


def bench_local_update():
    """One client local round (T=5) for the paper CNN — the unit of client
    work that the energy budget E_i pays for."""
    from repro.configs import get_config
    from repro.core.round import local_update
    from repro.models import get_model
    from repro.optim import adam
    cfg = get_config("cifar-cnn")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    T, B = 5, 32
    batch = {"images": jax.random.normal(jax.random.PRNGKey(1), (T, B, 32, 32, 3)),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (T, B), 0, 10)}
    f = jax.jit(lambda w, b, k: local_update(
        lambda p, bt, kk: model.loss_fn(p, bt), adam(1e-3), w, b, k, T))
    us = _timeit(f, params, batch, jax.random.PRNGKey(3), n=3, warmup=1)
    return [("local_update/cnn_T5_B32", us, "client-round")]


def bench_kernel_oracles():
    """jnp oracle paths (CPU): attention + SSD + fused agg reference costs.
    (Pallas kernels themselves target TPU; interpret-mode timing is not
    meaningful — correctness is covered in tests/test_kernels.py.)"""
    from repro.kernels import ref
    key = jax.random.PRNGKey(0)
    B, S, H, D = 4, 512, 8, 64
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, D),
                                 dtype=jnp.bfloat16) for i in range(3))
    att = jax.jit(lambda q, k, v: ref.mha_reference(q, k, v, causal=True))
    rows = [("kernel_oracle/attention_4x512x8x64",
             _timeit(att, q, k, v, n=5), "jnp-ref;bf16")]

    x = jax.random.normal(key, (2, 512, 8, 32))
    dt = jax.nn.softplus(jax.random.normal(key, (2, 512, 8)))
    A = -jnp.exp(jax.random.normal(key, (8,)) * 0.3)
    Bm = jax.random.normal(key, (2, 512, 8, 16)) * 0.3
    Cm = jax.random.normal(key, (2, 512, 8, 16)) * 0.3
    ssd = jax.jit(lambda *a: ref.ssd_reference(*a))
    rows.append(("kernel_oracle/ssd_2x512x8x32",
                 _timeit(ssd, x, dt, A, Bm, Cm, n=5), "jnp-ref;sequential"))

    from repro.models.ssm import ssd_chunked
    chk = jax.jit(lambda *a: ssd_chunked(*a, chunk=64)[0])
    rows.append(("kernel_oracle/ssd_chunked_2x512x8x32",
                 _timeit(chk, x, dt, A, Bm, Cm, n=5),
                 "jnp chunked (TPU-form oracle)"))
    return rows


def bench_theorem1_bound():
    """Theorem 1 bound values (sanity anchor for §Convergence)."""
    from repro.core import Theorem1Constants
    c = Theorem1Constants(mu=0.5, L=4.0, T=5, G2=25.0, sigma2=1.0,
                          gamma_het=0.2, E_max=20, w0_dist2=4.0)
    rows = []
    for K in (100, 1000, 10000):
        rows.append((f"theorem1/bound_K{K}", 0.0, f"bound={c.bound(K):.4f}"))
    return rows


def bench_roofline():
    """Summarise the dry-run roofline JSONs if present (§Roofline)."""
    try:
        from benchmarks.roofline import csv_rows, load_records
        recs = load_records()
        if not recs:
            return [("roofline/none", 0.0, "run repro.launch.dryrun first")]
        return csv_rows(recs)
    except Exception as e:  # noqa: BLE001
        return [("roofline/error", 0.0, str(e))]


def main() -> None:
    print("name,us_per_call,derived")
    benches = [bench_scheduler, bench_aggregation, bench_local_update,
               bench_kernel_oracles, bench_theorem1_bound, bench_fig1,
               bench_roofline]
    for bench in benches:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{bench.__name__}/ERROR,0.0,{type(e).__name__}:{e}",
                  flush=True)


if __name__ == "__main__":
    main()
