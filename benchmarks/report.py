"""Render EXPERIMENTS.md's data-driven sections from the dry-run JSONs and
benchmark results, so re-runs keep the doc in sync.

  PYTHONPATH=src python -m benchmarks.report > /tmp/sections.md
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks._fmt import manifest_line, md_table
from benchmarks.roofline import load_records

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
               "long_500k": 3}


def dryrun_section(result_dir="benchmarks/dryrun_results") -> str:
    out = ["### Single-pod (16x16, 256 chips) baselines", ""]
    recs = load_records(result_dir, "single")
    rows = []
    for r in sorted(recs, key=lambda x: (x["arch"],
                                         SHAPE_ORDER.get(x["shape"], 9))):
        mode = r["step_meta"].get("mode", r["kind"])
        rows.append([
            r["arch"], r["shape"], mode, r["compile_s"],
            f"{r['memory']['total_bytes_per_device']/2**30:.2f}",
            f"{r.get('collective_bytes_per_device', 0)/1e9:.2f}",
            f"{r['cost']['flops_per_device']:.3e}"])
    out.append(md_table(["arch", "shape", "mode", "compile(s)", "GiB/dev",
                         "coll GB/dev", "flops/dev"], rows))
    mrecs = load_records(result_dir, "multi")
    out += ["", "### Multi-pod (2x16x16, 512 chips) compile proof", ""]
    if mrecs:
        ok = len(mrecs)
        out.append(f"{ok} combos lowered+compiled on the multi-pod mesh "
                   f"(pod axis shards the client/batch dimension).")
        out.append("")
        rows = [[r["arch"], r["shape"], r["compile_s"],
                 f"{r['memory']['total_bytes_per_device']/2**30:.2f}"]
                for r in sorted(mrecs,
                                key=lambda x: (x["arch"],
                                               SHAPE_ORDER.get(x["shape"], 9)))]
        out.append(md_table(["arch", "shape", "compile(s)", "GiB/dev"], rows))
    return "\n".join(out)


def roofline_section(result_dir="benchmarks/dryrun_results") -> str:
    recs = load_records(result_dir, "single")
    rows = []
    for r in sorted(recs, key=lambda x: (x["arch"],
                                         SHAPE_ORDER.get(x["shape"], 9))):
        rf = r["roofline"]
        rows.append([
            r["arch"], r["shape"],
            f"{rf['t_compute_s']:.3e}", f"{rf['t_memory_s']:.3e}",
            f"{rf['t_collective_s']:.3e}", f"**{rf['dominant']}**",
            f"{rf['model_flops']:.2e}", f"{rf['useful_compute_ratio']:.2f}",
            ""])
    return md_table(["arch", "shape", "t_comp(s)", "t_mem(s)", "t_coll(s)",
                     "dominant", "MODEL_FLOPS", "useful",
                     "one-line diagnosis"], rows)


def fig1_section(path="benchmarks/results/fig1.json") -> str:
    if not os.path.exists(path):
        return "(fig1.json not yet generated)"
    with open(path) as f:
        data = json.load(f)
    rows = [[r["label"], f"{r['final_acc']:.3f}", r["wall_s"]]
            for r in data["results"].values()]
    return f"Config: {json.dumps(data['config'])}\n\n" \
        + md_table(["policy", "final test acc", "wall(s)"], rows)


def bench_section(path="BENCH_fleet.json") -> str:
    """Provenance + round-step timings of a committed ``BENCH_*.json``.

    Renders the embedded manifest block via `manifest_line` (pre-PR-7 files
    without one get an explicit placeholder, never a crash) and the
    ``round_step`` timing rows the CI bench-diff tripwire guards.
    """
    if not os.path.exists(path):
        return f"({path} not yet generated)"
    with open(path) as f:
        bench = json.load(f)
    out = [f"`{path}` — {manifest_line(bench)}", ""]
    steps = bench.get("round_step") or []
    if steps:
        rows = [[f"{r.get('num_clients', 0):,}", r.get("policy", "-"),
                 r.get("unfused_ms", "-"), r.get("lax_fused_ms", "-"),
                 r.get("pallas_ms", "-"),
                 r.get("speedup_fused_vs_unfused", "-")]
                for r in steps]
        out.append(md_table(["clients", "policy", "unfused ms",
                             "lax fused ms", "pallas ms", "speedup"], rows))
    else:
        out.append("(no round_step section)")
    return "\n".join(out)


if __name__ == "__main__":
    print("## §Dry-run\n")
    print(dryrun_section())
    print("\n## §Roofline\n")
    print(roofline_section())
    print("\n## §Fig1\n")
    print(fig1_section())
    print("\n## §Bench provenance\n")
    for p in ("BENCH_fleet.json", "BENCH_serve.json", "BENCH_traces.json"):
        print(bench_section(p))
        print()
