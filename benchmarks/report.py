"""Render EXPERIMENTS.md's data-driven sections from the dry-run JSONs and
benchmark results, so re-runs keep the doc in sync.

  PYTHONPATH=src python -m benchmarks.report > /tmp/sections.md
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline import load_records

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
               "long_500k": 3}


def dryrun_section(result_dir="benchmarks/dryrun_results") -> str:
    out = ["### Single-pod (16x16, 256 chips) baselines", ""]
    recs = load_records(result_dir, "single")
    out.append("| arch | shape | mode | compile(s) | GiB/dev | coll GB/dev | "
               "flops/dev |")
    out.append("|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda x: (x["arch"],
                                         SHAPE_ORDER.get(x["shape"], 9))):
        mode = r["step_meta"].get("mode", r["kind"])
        out.append(
            f"| {r['arch']} | {r['shape']} | {mode} "
            f"| {r['compile_s']} "
            f"| {r['memory']['total_bytes_per_device']/2**30:.2f} "
            f"| {r.get('collective_bytes_per_device', 0)/1e9:.2f} "
            f"| {r['cost']['flops_per_device']:.3e} |")
    mrecs = load_records(result_dir, "multi")
    out += ["", "### Multi-pod (2x16x16, 512 chips) compile proof", ""]
    if mrecs:
        ok = len(mrecs)
        out.append(f"{ok} combos lowered+compiled on the multi-pod mesh "
                   f"(pod axis shards the client/batch dimension).")
        out.append("")
        out.append("| arch | shape | compile(s) | GiB/dev |")
        out.append("|---|---|---|---|")
        for r in sorted(mrecs, key=lambda x: (x["arch"],
                                              SHAPE_ORDER.get(x["shape"], 9))):
            out.append(f"| {r['arch']} | {r['shape']} | {r['compile_s']} "
                       f"| {r['memory']['total_bytes_per_device']/2**30:.2f} |")
    return "\n".join(out)


def roofline_section(result_dir="benchmarks/dryrun_results") -> str:
    recs = load_records(result_dir, "single")
    out = ["| arch | shape | t_comp(s) | t_mem(s) | t_coll(s) | dominant | "
           "MODEL_FLOPS | useful | one-line diagnosis |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda x: (x["arch"],
                                         SHAPE_ORDER.get(x["shape"], 9))):
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {rf['t_compute_s']:.3e} | {rf['t_memory_s']:.3e} "
            f"| {rf['t_collective_s']:.3e} | **{rf['dominant']}** "
            f"| {rf['model_flops']:.2e} | {rf['useful_compute_ratio']:.2f} | |")
    return "\n".join(out)


def fig1_section(path="benchmarks/results/fig1.json") -> str:
    if not os.path.exists(path):
        return "(fig1.json not yet generated)"
    with open(path) as f:
        data = json.load(f)
    out = [f"Config: {json.dumps(data['config'])}", "",
           "| policy | final test acc | wall(s) |", "|---|---|---|"]
    for k, r in data["results"].items():
        out.append(f"| {r['label']} | {r['final_acc']:.3f} | {r['wall_s']} |")
    return "\n".join(out)


if __name__ == "__main__":
    print("## §Dry-run\n")
    print(dryrun_section())
    print("\n## §Roofline\n")
    print(roofline_section())
    print("\n## §Fig1\n")
    print(fig1_section())
